"""Paper Fig. 6 — GEMM performance across data types at 512².

Model layer: speedups per dtype/backend with the paper's MAC-unit PPA
constraints (Table 2: int @1 GHz, fp @600 MHz; fp16 CPU penalty §4.3.2).
Host layer: Pallas kernel (interpret) per dtype vs oracle for throughput
sanity + correctness.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import sysmodel as SM
from repro.kernels.matrixflow_gemm import matrixflow_gemm


def run():
    wl = ((SM.Gemm(512, 512, 512),), ())
    for dt in ("int8", "int16", "int32", "fp16", "fp32"):
        t = SM.speedup_table(wl, dt)
        emit("fig6_dtype", f"accel_dc_{dt}", round(t["mf_dc"], 1), "x")
        emit("fig6_dtype", f"neon_{dt}", round(t["neon"], 1), "x")
        emit("fig6_dtype", f"omp_{dt}", round(t["omp"], 1), "x")

    # host-side kernel sweep (correctness + relative cost)
    rng = np.random.default_rng(0)
    for dt, tol in ((jnp.float32, 1e-4), (jnp.bfloat16, 5e-2),
                    (jnp.int8, 0)):
        if dt == jnp.int8:
            a = jnp.asarray(rng.integers(-8, 8, (256, 256)).astype(np.int8))
            b = jnp.asarray(rng.integers(-8, 8, (256, 256)).astype(np.int8))
        else:
            a = jnp.asarray(rng.standard_normal((256, 256),
                                                np.float32)).astype(dt)
            b = jnp.asarray(rng.standard_normal((256, 256),
                                                np.float32)).astype(dt)
        t = time_fn(lambda a=a, b=b: matrixflow_gemm(a, b, interpret=True),
                    warmup=1, iters=2)
        ref = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
        out = matrixflow_gemm(a, b, interpret=True).astype(jnp.float32)
        err = float(jnp.abs(out - ref).max())
        ok = err <= max(tol * float(jnp.abs(ref).max()), 1e-3)
        emit("fig6_dtype", f"kernel_interpret_{jnp.dtype(dt).name}",
             round(t * 1e3, 1), "ms", max_err=f"{err:.1e}", ok=ok)


if __name__ == "__main__":
    run()
