"""Shared benchmark utilities: wall-clock timing of jitted callables +
CSV row emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

ROWS: List[Dict] = []


def emit(bench: str, name: str, value, unit: str, **extra):
    row = {"bench": bench, "name": name, "value": value, "unit": unit}
    row.update(extra)
    ROWS.append(row)
    tail = " ".join(f"{k}={v}" for k, v in extra.items())
    print(f"[{bench}] {name}: {value} {unit} {tail}".rstrip())


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def dump_csv(path: str):
    import csv
    keys = ["bench", "name", "value", "unit"]
    extra = sorted({k for r in ROWS for k in r} - set(keys))
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys + extra)
        w.writeheader()
        w.writerows(ROWS)
    print(f"[benchmarks] wrote {len(ROWS)} rows to {path}")
