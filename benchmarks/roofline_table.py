"""§Roofline — the 40-cell (arch × shape) roofline table from the dry-run
artifacts (dryrun_single.jsonl / dryrun_multi.jsonl, produced by
``python -m repro.launch.dryrun --all [--multi-pod] --out <file>``).

Per cell: the three terms (compute/memory/collective, seconds), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and bytes/device.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

REPO = os.path.join(os.path.dirname(__file__), "..")


def _load_rows(mesh_label):
    """Rows for a mesh from dryrun_both.jsonl or the per-mesh legacy files."""
    for fname in ("dryrun_final.jsonl", "dryrun_both.jsonl",
                  "dryrun_single.jsonl", "dryrun_multi.jsonl"):
        path = os.path.join(REPO, fname)
        if not os.path.exists(path):
            continue
        rows = [json.loads(line) for line in open(path) if line.strip()]
        rows = [r for r in rows
                if r.get("mesh", mesh_label) == mesh_label or r.get("skipped")]
        if rows:
            return rows
    return None


def run():
    for mesh_label in ("16x16", "2x16x16"):
        rows = _load_rows(mesh_label)
        if rows is None:
            emit("roofline", f"{mesh_label}", "skipped (no dryrun jsonl)", "")
            continue
        n_ok = n_skip = 0
        worst = None
        seen = set()
        for r in rows:
            key = (r.get("arch"), r.get("shape"))
            if key in seen:
                continue
            seen.add(key)
            if r.get("skipped"):
                n_skip += 1
                continue
            if "error" in r:
                emit("roofline", f"{r['arch']}x{r['shape']}@{mesh_label}",
                     "ERROR", "", detail=r["error"][:80])
                continue
            n_ok += 1
            t = r["roofline"]
            name = f"{r['arch']}×{r['shape']}@{mesh_label}"
            emit("roofline", name,
                 t["bottleneck"], "bottleneck",
                 t_compute=f"{t['t_compute_s']:.2e}",
                 t_memory=f"{t['t_memory_s']:.2e}",
                 t_collective=f"{t['t_collective_s']:.2e}",
                 roofline_fraction=round(t["roofline_fraction"], 3),
                 useful_flops=round(t.get("useful_flops_ratio", 0), 3),
                 gb_per_device=r["memory"].get("total_gb_per_device"))
            if worst is None or (t["roofline_fraction"]
                                 < worst[1]):
                worst = (name, t["roofline_fraction"])
        emit("roofline", f"summary_{mesh_label}",
             f"{n_ok} cells ok, {n_skip} skipped (long_500k non-SSM)", "",
             worst_cell=worst[0] if worst else "",
             worst_fraction=round(worst[1], 4) if worst else "")


if __name__ == "__main__":
    run()
