"""§Perf hillclimb driver: run a dry-run cell under a named variant and
report the roofline-term deltas vs baseline.

Variants are the experiment arms of EXPERIMENTS.md §Perf:

  baseline      the paper-faithful configuration as swept
  seqpar        activation sequence parallelism (act_seq → model axis)
  kvseq         KV-cache sequence sharding (act_kv_seq → model axis):
                flash-decode-style partial-softmax with small all-reduces
  dots          remat policy 'dots' (save MXU outputs, recompute elementwise)
  noremat       remat off (memory-for-traffic trade)
  mb4 / mb8     gradient-accumulation microbatching (train cells)
  batch2d       batch sharded over (data × model) (frees the model axis
                for archs whose heads don't divide it)

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --arch smollm-135m \
      --shape train_4k --variants baseline seqpar batch2d
"""
from __future__ import annotations

import argparse
import dataclasses
import json

VARIANTS = ("baseline", "seqpar", "kvseq", "dots", "noremat", "mb4", "mb8",
            "batch2d", "seqpar_dots", "kvseq_batch2d")


def run_variant(arch: str, shape: str, variant: str, multi_pod: bool = False):
    # import inside: dryrun sets XLA_FLAGS at import time
    import repro.launch.dryrun as DR
    import repro.configs.registry as REG
    import repro.launch.steps as ST

    overrides = {}
    cfg_patch = {}
    microbatches = 1
    for piece in variant.split("_"):
        if piece == "seqpar":
            overrides["act_seq"] = "model"
        elif piece == "kvseq":
            overrides["act_kv_seq"] = "model"
        elif piece == "dots":
            cfg_patch["remat_policy"] = "dots"
        elif piece == "noremat":
            cfg_patch["remat"] = False
        elif piece.startswith("mb"):
            microbatches = int(piece[2:])
        elif piece == "batch2d":
            overrides["act_batch"] = ("pod", "data", "model")
        elif piece == "baseline":
            pass
        else:
            raise ValueError(piece)

    orig_get = REG.get_config
    orig_step = ST.make_train_step_fn

    def patched_get(a):
        c = orig_get(a)
        return dataclasses.replace(c, **cfg_patch) if cfg_patch else c

    def patched_step(cfg, opt_cfg=None, total_steps=10000, **kw):
        kw.setdefault("microbatches", microbatches)
        return orig_step(cfg, opt_cfg, total_steps, **kw)

    DR.get_config = patched_get
    ST_make = ST.make_train_step_fn
    ST.make_train_step_fn = patched_step
    DR.ST.make_train_step_fn = patched_step
    try:
        r = DR.dryrun_cell(arch, shape, multi_pod=multi_pod,
                           rules_overrides=overrides or None, verbose=False)
    finally:
        DR.get_config = orig_get
        ST.make_train_step_fn = ST_make
        DR.ST.make_train_step_fn = ST_make
    r["variant"] = variant
    return r


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", nargs="+", default=["baseline"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    base = None
    for v in args.variants:
        try:
            r = run_variant(args.arch, args.shape, v, args.multi_pod)
        except Exception as e:
            import traceback
            traceback.print_exc()
            print(f"[hillclimb] {v}: FAILED {type(e).__name__}: {e}")
            continue
        t = r["roofline"]
        if base is None and v == "baseline":
            base = t
        def rel(key):
            if base is None or base[key] == 0:
                return ""
            return f" ({t[key] / base[key]:.2f}x)"
        print(f"[hillclimb] {args.arch}×{args.shape} [{v}]: "
              f"bottleneck={t['bottleneck']} "
              f"tc={t['t_compute_s']:.3e}{rel('t_compute_s')} "
              f"tm={t['t_memory_s']:.3e}{rel('t_memory_s')} "
              f"tx={t['t_collective_s']:.3e}{rel('t_collective_s')} "
              f"frac={t['roofline_fraction']:.3f} "
              f"mem={r['memory'].get('total_gb_per_device', '?')}GB "
              f"compile={r['compile_s']}s")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(r) + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
