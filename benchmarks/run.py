"""Benchmark driver: one module per paper table/figure, plus the roofline
table from the dry-run artifacts. Emits benchmarks/results.csv.

  python -m benchmarks.run               # all
  python -m benchmarks.run fig7 table3   # subset
"""
from __future__ import annotations

import os
import sys

from benchmarks import (attention_sweep, gemm_dtype_sweep, gemm_size_sweep,
                        interconnect_sweep, roofline_table, runtime_breakdown,
                        serving_sweep, transformer_e2e)
from benchmarks.common import dump_csv

SUITES = {
    "fig7": gemm_size_sweep.run,
    "fig6": gemm_dtype_sweep.run,
    "table3": transformer_e2e.run,
    "fig8": runtime_breakdown.run,
    "fig9": interconnect_sweep.run,
    "roofline": roofline_table.run,
    "attention": attention_sweep.run,
    "serving": serving_sweep.run,
    # TP column: paged serving over a (data, model) host mesh (skips with
    # a message on 1-device hosts; force devices via XLA_FLAGS)
    "serving-tp": serving_sweep.run_tp,
    # prefix-cache acceptance: shared-prefix + bursty Poisson mixes with
    # and without COW prompt-page sharing at a fixed pool size
    "serving-prefix": serving_sweep.run_prefix,
    # quantized-KV capacity: bf16 vs int8 KV pages at an equal pool-byte
    # budget (gate: >=1.8x peak resident requests under int8)
    "serving-kv": serving_sweep.run_kv,
    # speculative decoding: greedy vs n-gram self-speculation at
    # token-identical streams (gate: >=1.5x tokens/s for the spec cell)
    "serving-spec": serving_sweep.run_spec,
}


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    picks = [a for a in argv if not a.startswith("-")] or list(SUITES)
    for name in picks:
        print(f"\n===== {name} =====")
        SUITES[name]()
    out = os.path.join(os.path.dirname(__file__), "results.csv")
    dump_csv(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
