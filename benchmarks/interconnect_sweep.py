"""Paper Fig. 9 — interconnect bandwidth sensitivity.

Two halves:
  * the paper's experiment: GEMM-1024 runtime under PCIe 16L-64Gbps /
    4L-16Gbps / 4L-5Gbps (calibrated system model);
  * the TPU translation: the same sensitivity applied to the *collective*
    roofline term of the dry-run cells — ICI link bandwidth is the TPU's
    "PCIe", so we sweep it and report how each mesh-level workload's
    bottleneck moves (reads dryrun_single.jsonl when present).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.core import sysmodel as SM


def run():
    # -- paper experiment ----------------------------------------------------
    base = None
    for label, gbps in (("16L_64G", 64.0), ("4L_16G", 16.0), ("4L_5G", 5.0)):
        sys = SM.SystemConfig(pcie_total_gbps=gbps)
        t = SM.workload_time(((SM.Gemm(1024, 1024, 1024),), ()),
                             "int32", "mf_dc", sys)["total"]
        base = base or t
        emit("fig9_interconnect", f"gemm1024_{label}",
             round(t * 1e3, 2), "ms", slowdown=round(t / base, 2),
             paper="~2.3x worst/best" if label == "4L_5G" else "")

    # -- TPU translation: ICI bandwidth sweep over dry-run cells -------------
    repo = os.path.join(os.path.dirname(__file__), "..")
    rows = []
    for fname in ("dryrun_final.jsonl", "dryrun_both.jsonl",
                  "dryrun_single.jsonl"):
        path = os.path.join(repo, fname)
        if os.path.exists(path):
            rows = [json.loads(line) for line in open(path) if line.strip()]
            break
    rows = [r for r in rows
            if "roofline" in r and r.get("mesh", "16x16") == "16x16"]
    if not rows:
        emit("fig9_interconnect", "ici_sweep", "skipped (no dryrun jsonl)", "")
        return
    for factor, label in ((1.0, "ici_50GBps"), (0.25, "ici_12.5GBps"),
                          (4.0, "ici_200GBps")):
        moved = 0
        coll_bound = 0
        for r in rows:
            t = r["roofline"]
            tc, tm = t["t_compute_s"], t["t_memory_s"]
            tx = t["t_collective_s"] / factor
            new_b = max(("compute", tc), ("memory", tm),
                        ("collective", tx), key=lambda kv: kv[1])[0]
            coll_bound += new_b == "collective"
            moved += new_b != t["bottleneck"]
        emit("fig9_interconnect", f"{label}_collective_bound_cells",
             coll_bound, f"/{len(rows)}", bottleneck_moved=moved)


if __name__ == "__main__":
    run()
