"""Attention backend sweep: fused flash kernel vs the unfused baseline.

Times api.attention under each registered AttentionPolicy backend across the
shapes that dominate serving — prefill (square, GQA) and decode (Sq=1
against a long cache with per-row offsets) — and reports each cell's
correctness (the ``ok``/``max_err`` columns) against
kernels/ref.py::mha_ref, reusing tests/parity.py's attention operands and
tolerances so the numbers can never drift from the parity gate's. The hard
pass/fail gate itself lives in tests/test_parity.py, not here.

On CPU the fused backend runs in interpret mode (a correctness substrate,
not a speed one), so the interesting CPU number is the unfused baseline;
on TPU swap in backend "fused" for the real kernel. ``--backend`` pins one.

  python -m benchmarks.attention_sweep
  python -m benchmarks.attention_sweep --backend unfused --decode-cache 4096
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import api
from repro.core.plan import AttentionPolicy


def _load_parity():
    """Import tests/parity.py — the single source of attention operands,
    the mha_ref oracle wiring, and per-dtype tolerances."""
    import importlib
    import os
    import sys
    tests_dir = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    return importlib.import_module("parity")


def sweep(backends: Sequence[str], dtype: str = "float32",
          decode_cache: int = 512):
    parity = _load_parity()
    cases = list(parity.ATTN_CASES) + [
        # a serving-sized decode cell: full slots, long cache, ragged fills
        parity.AttnCase("decode_serving", B=8, Sq=1, T=decode_cache,
                        H=8, Hkv=2,
                        q_offsets=tuple(
                            (decode_cache * (i + 1)) // 9 for i in range(8)),
                        kv_lens=tuple(
                            (decode_cache * (i + 1)) // 9 + 1
                            for i in range(8))),
        parity.AttnCase("prefill_1k", B=1, Sq=1024, T=1024, H=8, Hkv=2),
    ]
    refs = {}          # oracle per case — backend-independent, compute once
    for backend in backends:
        pol = AttentionPolicy(backend=backend)
        for case in cases:
            q, k, v, qp, kl = parity.make_attention_operands(case, dtype)
            def fn(q=q, k=k, v=v, qp=qp, kl=kl, case=case, pol=pol):
                return api.attention(q, k, v, q_positions=qp,
                                     kv_valid_len=kl, causal=case.causal,
                                     policy=pol)
            t = time_fn(fn, warmup=1, iters=3)
            if case.name not in refs:
                refs[case.name] = np.asarray(parity.mha_ref(
                    q, k, v, causal=case.causal, q_positions=qp,
                    kv_valid_len=kl), np.float32)
            ref = refs[case.name]
            got = np.asarray(fn(), np.float32)
            err = float(np.abs(got - ref).max())
            atol, rtol = parity.ATTN_TOLS[dtype]
            ok = bool(np.allclose(got, ref, atol=atol, rtol=rtol))
            # attention FLOPs ≈ 4·B·H·Sq·T_eff·D (QKᵀ + PV), T_eff = mean
            # valid keys — offsets make the fused kernel's work ragged
            t_eff = float(jnp.mean(jnp.minimum(kl, case.T)))
            flops = 4 * case.B * case.H * case.Sq * t_eff * q.shape[-1]
            emit("attention", f"{backend}_{case.name}_{dtype}",
                 round(t * 1e3, 3), "ms",
                 gflops=round(flops / t / 1e9, 2),
                 max_err=f"{err:.1e}", ok=ok)


def run():
    """Default suite entry (benchmarks.run): CPU-safe backends."""
    sweep(("unfused", "fused_interpret"), dtype="float32", decode_cache=256)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default=None,
                    help="pin one attention backend (default: unfused + "
                         "fused_interpret)")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--decode-cache", type=int, default=512,
                    help="KV cache length of the serving decode cell")
    args = ap.parse_args(argv)
    backends = ((args.backend,) if args.backend
                else ("unfused", "fused_interpret"))
    sweep(backends, dtype=args.dtype, decode_cache=args.decode_cache)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
