"""Paper Table 3 — end-to-end transformer speedups (BERT medium/base/large,
ViT base/large/huge) vs single-thread CPU, across all modeled backends.

Prints model-vs-paper ratios; the ±40 % acceptance band is enforced by
tests/test_sysmodel.py. Also times a real reduced-BERT forward on this host
through the XLA vs MatrixFlow(blockflow) paths as an implementation-level
sanity check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import api
from repro.core import sysmodel as SM
from repro.core.workloads import PAPER_TABLE3, paper_workload


def run():
    for model, ref in PAPER_TABLE3.items():
        t = SM.speedup_table(paper_workload(model), "int32")
        for backend in ("omp", "smaug", "ticsat", "mf_dc"):
            paper_val = ref.get(backend)
            emit("table3_e2e", f"{model}_{backend}",
                 round(t[backend], 1), "x",
                 paper=paper_val if paper_val else "",
                 ratio=(round(t[backend] / paper_val, 2)
                        if paper_val else ""))

    # host-level: reduced BERT forward, XLA vs blockflow GEMM path
    from repro.models import transformer as T
    cfg = T.bert_config("medium")
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, n_heads=4,
                              n_kv_heads=4, d_ff=512, vocab=1024)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((2, 64), jnp.int32)}

    def fwd_xla():
        with api.use_policy(api.GemmPolicy(backend="xla")):
            return T.forward(params, cfg, batch)[0]

    def fwd_mf():
        with api.use_policy(api.GemmPolicy(backend="blockflow")):
            return T.forward(params, cfg, batch)[0]

    t_x = time_fn(fwd_xla, warmup=1, iters=2)
    t_m = time_fn(fwd_mf, warmup=1, iters=2)
    emit("table3_e2e", "host_bert_reduced_xla", round(t_x * 1e3, 1), "ms")
    emit("table3_e2e", "host_bert_reduced_blockflow", round(t_m * 1e3, 1),
         "ms", note="Algorithm-1 lax rendering; Pallas kernel serves on TPU")


if __name__ == "__main__":
    run()
